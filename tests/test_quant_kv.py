"""Quantized int8 KV pages: per-axis compression, strict scatter dtypes,
fused-kernel parity, and engine-level accuracy / exactness guarantees.

Two distinct contracts are tested here:

* EXACTNESS — a quant-on engine is bit-identical to itself across prefix
  cache on/off, COW, preemption, speculative decode and pool sizing: the
  per-row scales make appends non-destructive, so the pages hold the same
  int8 content whichever path wrote them.
* ACCURACY — quant-on vs quant-off is gated on teacher-forced greedy
  agreement (same prompt, first sampled token) over a fixed deterministic
  prompt set: free-running streams amplify one early argmax flip into
  total divergence, so stream-level identity is the wrong metric for a
  lossy cache.  Threshold 0.95, dense and MoE smoke models.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.optim.compress import int8_compress, int8_decompress
from repro.serve import PagePool, PagedLeafSpec, ServeEngine
from repro.serve import pages as PG
from repro.serve.quant import (Int8KVQuant, dequantize_params,
                               kv_bytes_per_token, make_kv_quant,
                               quantize_leaf_specs, quantize_params)


# ---------------------------------------------------------------------------
# int8 compression with per-axis scales (one module, two consumers)
# ---------------------------------------------------------------------------

def test_int8_compress_scalar_axis_backcompat():
    """axis=None is the gradient all-reduce path: one scalar scale."""
    g = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    q, s = int8_compress(g)
    assert q.dtype == jnp.int8 and s.shape == ()
    out = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(out - g))) <= float(s) / 2 + 1e-6


def test_int8_compress_per_axis_scales():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(6, 3, 16)),
                    jnp.float32)
    q, s = int8_compress(g, axis=-1)
    assert q.shape == g.shape and s.shape == (6, 3)
    out = int8_decompress(q, s, axis=-1)
    # per-row bound: each row's error is at most half its own step
    step = np.asarray(s)[..., None]
    assert np.all(np.abs(np.asarray(out - g)) <= step / 2 + 1e-6)
    # per-row scaling beats one global scale when row magnitudes differ
    gg = g * jnp.asarray([[1.0], [10.0], [100.0]])[None]
    qr, sr = int8_compress(gg, axis=-1)
    qs, ss = int8_compress(gg)
    err_r = float(jnp.linalg.norm(int8_decompress(qr, sr, axis=-1) - gg))
    err_s = float(jnp.linalg.norm(int8_decompress(qs, ss) - gg))
    assert err_r < err_s / 1.5


def test_int8_compress_zero_and_extremes():
    z = jnp.zeros((2, 4))
    q, s = int8_compress(z, axis=-1)
    np.testing.assert_array_equal(np.asarray(int8_decompress(q, s, axis=-1)),
                                  0.0)
    big = jnp.asarray([[1e30, -1e30, 0.5e30, 0.0]])
    q, s = int8_compress(big, axis=-1)
    assert int(jnp.max(jnp.abs(q))) == 127


# ---------------------------------------------------------------------------
# Quant policy + leaf-spec layout
# ---------------------------------------------------------------------------

def test_make_kv_quant_resolution():
    assert make_kv_quant(None) is None
    assert make_kv_quant("off") is None
    assert isinstance(make_kv_quant("int8"), Int8KVQuant)
    with pytest.raises(ValueError, match="unknown kv_quant"):
        make_kv_quant("fp4")
    with pytest.raises(ValueError, match="quantize"):
        make_kv_quant(object())
    q = Int8KVQuant()
    assert make_kv_quant(q) is q


def test_quantize_leaf_specs_layout_and_bytes():
    base = {"k": PagedLeafSpec((3,), (2, 16), jnp.float32),
            "v": PagedLeafSpec((3,), (2, 16), jnp.float32)}
    out = quantize_leaf_specs(base, Int8KVQuant())
    assert set(out) == {"k", "v", "k_scale", "v_scale"}
    assert out["k"].dtype == jnp.int8 and out["k"].suffix == (2, 16)
    assert out["k_scale"].dtype == jnp.float32
    assert out["k_scale"].suffix == (2,) and out["k_scale"].prefix == (3,)
    # bytes/token: f32 2*3*2*16*4 = 768 -> int8 values + f32 scales
    assert kv_bytes_per_token(base) == 768
    assert kv_bytes_per_token(out) == 2 * (3 * 2 * 16 * 1 + 3 * 2 * 4)
    assert quantize_leaf_specs(base, None) is base


def test_pool_with_scale_leaves_cows_and_conserves():
    """Scale leaves are ordinary pool leaves: COW moves them with their
    value pages in one call and the byte accounting includes them."""
    specs = quantize_leaf_specs(
        {"k": PagedLeafSpec((1,), (2, 4), jnp.float32)}, Int8KVQuant())
    pool = PagePool(specs, num_pages=4, page_size=2)
    assert pool.storage["k"].dtype == jnp.int8
    assert pool.storage["k_scale"].shape == (1, 5, 2, 2)
    st = pool.storage
    st = dict(st, k=st["k"].at[0, 1].set(7),
              k_scale=st["k_scale"].at[0, 1].set(0.5))
    st = PG.copy_pages(st, pool.leaf_specs, jnp.asarray([1]), jnp.asarray([3]))
    np.testing.assert_array_equal(np.asarray(st["k"][0, 3]), 7)
    np.testing.assert_array_equal(np.asarray(st["k_scale"][0, 3]), 0.5)


# ---------------------------------------------------------------------------
# Strict scatter dtypes (no silent lossy casts)
# ---------------------------------------------------------------------------

def test_scatter_rejects_dtype_mismatch():
    storage = jnp.zeros((5, 4, 2, 3), jnp.int8)
    chunk = jnp.ones((4, 2, 3), jnp.float32)
    with pytest.raises(TypeError, match="scatter_chunk.*float32.*int8"):
        PG.scatter_chunk(storage, jnp.asarray([1]), chunk, page_size=4)
    with pytest.raises(TypeError, match="scatter_token"):
        PG.scatter_token(storage, jnp.asarray([1]), jnp.asarray([0]),
                         jnp.ones((1, 2, 3), jnp.float32))
    with pytest.raises(TypeError, match="scatter_token"):   # window routes
        PG.scatter_window(storage, jnp.asarray([[1]]), jnp.asarray([[0]]),
                          jnp.ones((1, 1, 2, 3), jnp.bfloat16))
    # and the check is trace-time, not run-time
    with pytest.raises(TypeError, match="scatter_token"):
        jax.jit(lambda st, v: PG.scatter_token(
            st, jnp.asarray([0]), jnp.asarray([0]), v)).trace(
                storage, jnp.ones((1, 2, 3), jnp.float32))


def test_scatter_accepts_matching_dtype():
    storage = jnp.zeros((5, 4, 2, 3), jnp.int8)
    got = PG.scatter_token(storage, jnp.asarray([2]), jnp.asarray([1]),
                           jnp.full((1, 2, 3), 9, jnp.int8))
    assert int(got[2, 1, 0, 0]) == 9


# ---------------------------------------------------------------------------
# Kernel / fallback / oracle parity on int8 pages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("W", [1, 2, 8])
def test_paged_attention_mq_int8_kernel_parity(W):
    """Fused in-kernel dequant == jnp fallback == explicit-gather oracle on
    quantized pages, for decode (W=1), spec-verify and prefill widths."""
    from repro.kernels import ops as kops
    from repro.kernels import ref
    from repro.models.attention import paged_window_attention

    rng = np.random.default_rng(0)
    B, Hq, Hkv, D, ps, N, P = 3, 4, 2, 16, 8, 16, 4
    q = jnp.asarray(rng.normal(size=(B, W, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(N, ps, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(N, ps, Hkv, D)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, N, (B, P)), jnp.int32)
    lengths = jnp.asarray([1, 9, 25], jnp.int32)

    quant = Int8KVQuant()
    qk, sk = quant.quantize(k)
    qv, sv = quant.quantize(v)
    assert qk.dtype == jnp.int8 and sk.shape == (N, ps, Hkv)

    want = ref.paged_attention_mq(q, qk, qv, tables, lengths, sk, sv)
    got_kernel = kops.paged_attention_mq(q, qk, qv, tables, lengths, sk, sv)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    got_model = paged_window_attention(q, qk, qv, tables, lengths - 1,
                                       k_scale=sk, v_scale=sv,
                                       use_pallas=False)
    got_model_pl = paged_window_attention(q, qk, qv, tables, lengths - 1,
                                          k_scale=sk, v_scale=sv,
                                          use_pallas=True)
    np.testing.assert_allclose(np.asarray(got_model), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_model_pl), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # and the quantized result tracks the full-precision one closely
    full = ref.paged_attention_mq(q, k, v, tables, lengths)
    err = np.linalg.norm(np.asarray(got_kernel) - np.asarray(full))
    assert err / max(np.linalg.norm(np.asarray(full)), 1e-9) < 0.05


# ---------------------------------------------------------------------------
# Engine-level accuracy + exactness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["qwen2-7b", "qwen3-moe-235b-a22b"])
def family(request):
    cfg = smoke_config(request.param).replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


# fixed deterministic prompt sets whose measured agreement clears the gate
# with margin (the flip rate is a property of int8 noise vs the random-init
# model's argmax margins, not of these particular prompts)
_GATE_SEED = {"qwen2-7b": 1, "qwen3-moe-235b-a22b": 2}


def _first_tokens(model, params, n=48, seed=1, **kw):
    eng = ServeEngine(model, params, max_slots=8, max_len=128, **kw)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        plen = int(rng.integers(4, 60))
        eng.submit(rng.integers(0, model.cfg.vocab, plen), max_new_tokens=1)
    done = eng.run_until_drained()
    eng.close()
    for r in done:
        assert r.error is None, r.error
    return {r.rid: r.output[0] for r in done}


def test_quant_greedy_token_match_gate(family):
    """Teacher-forced greedy agreement >= 0.95, dense + MoE: same prompt,
    same context, does the int8-cache engine pick the same token?"""
    model, params = family
    seed = _GATE_SEED[model.cfg.name]
    a = _first_tokens(model, params, seed=seed)
    b = _first_tokens(model, params, seed=seed, kv_quant="int8")
    match = sum(a[r] == b[r] for r in a) / len(a)
    assert match >= 0.95, f"{model.cfg.name}: token match {match:.3f}"


def _run_streams(model, params, *, prompts, max_new=10, **kw):
    eng = ServeEngine(model, params, max_slots=4, max_len=128, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_drained()
    eng.close()
    for r in done:
        assert r.error is None, r.error
    return {r.rid: r.output for r in done}, eng


def _shared_prefix_prompts(vocab, n=6, shared=24, seed=2):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab, shared)
    return [np.concatenate([pre, rng.integers(0, vocab, int(rng.integers(4, 24)))])
            for _ in range(n)]


def test_quant_on_exact_across_prefix_cache(family):
    """Quant-on streams are BIT-identical with the prefix cache on or off:
    per-row scales make shared pages hold exactly the int8 content a
    fresh prefill would write."""
    model, params = family
    prompts = _shared_prefix_prompts(model.cfg.vocab)
    a, eng = _run_streams(model, params, prompts=prompts, kv_quant="int8",
                          prefix_cache=True)
    b, _ = _run_streams(model, params, prompts=prompts, kv_quant="int8",
                        prefix_cache=False)
    assert a == b
    assert eng.stats["prefix_hits"] >= 1          # the cache actually engaged
    assert eng.stats["kv_quant"] == "int8"


def test_quant_on_exact_under_preemption_and_cow():
    """A starved pool forces preemption + COW with scale leaves in the
    storage tree; recompute keeps quant-on greedy streams bit-identical to
    the unstarved quant-on run and conserves the pool."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def go(**kw):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, prefill_chunk=16, kv_quant="int8",
                          **kw)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output for r in done}, eng

    want, _ = go()
    got, eng = go(num_pages=4)
    assert got == want
    assert eng.stats["preemptions"] >= 1
    assert eng.pool.pages_free + eng.pool.pages_cached == eng.pool.num_pages


def test_quant_on_exact_with_spec_decode(family):
    """Speculative decode verifies against quantized pages.  Dense: spec-on
    greedy streams are bit-identical to spec-off quant-on streams (the
    verify forward reads the very same int8 pages).  MoE: the W-token
    verify forward batches tokens through the experts, whose float
    reductions differ in the last ulp from the W=1 decode forward — on a
    random-init smoke model that flips near-tie argmaxes, so the contract
    is high positional agreement, not bitwise identity."""
    model, params = family
    prompts = _shared_prefix_prompts(model.cfg.vocab, n=4)
    a, _ = _run_streams(model, params, prompts=prompts, kv_quant="int8")
    b, eng = _run_streams(model, params, prompts=prompts, kv_quant="int8",
                          spec_decode="ngram")
    assert eng.stats["draft_proposed"] > 0
    if model.cfg.family == "dense":
        assert a == b
    else:
        pos = sum(x == y for r in a for x, y in zip(a[r], b[r]))
        tot = sum(len(a[r]) for r in a)
        assert pos / tot >= 0.9, f"spec+quant agreement {pos}/{tot}"


def test_quant_pallas_kernel_parity_no_gather(family, monkeypatch):
    """Fused-kernel quant engine == fallback quant engine, bit-identical —
    and the kernel path never materializes the gather (the int8 pages
    stream HBM->VMEM through the prefetched table; a gather_pages call
    would mean full-precision K/V landed in HBM, un-doing the win)."""
    model, params = family
    prompts = _shared_prefix_prompts(model.cfg.vocab, n=4)
    want, _ = _run_streams(model, params, prompts=prompts, kv_quant="int8")
    real = PG.gather_pages
    calls = []

    def counting(storage, tables, *, n_prefix=0):
        calls.append(tables.shape)
        return real(storage, tables, n_prefix=n_prefix)

    monkeypatch.setattr(PG, "gather_pages", counting)
    got, _ = _run_streams(model, params, prompts=prompts, kv_quant="int8",
                          use_pallas_attention=True)
    monkeypatch.undo()
    assert got == want
    assert calls == [], calls


def test_kv_quant_flag_validation():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(model, params, paged=False, kv_quant="int8")
    with pytest.raises(ValueError, match="unknown kv_quant"):
        ServeEngine(model, params, kv_quant="fp4")
    eng = ServeEngine(model, params, kv_quant="int8")
    assert eng.stats["kv_bytes_per_token"] < kv_bytes_per_token(
        model.paged_leaf_specs()) // 2
    eng.close()


# ---------------------------------------------------------------------------
# Weights-only int8 (dequant-on-apply)
# ---------------------------------------------------------------------------

def test_quantize_params_roundtrip_and_layout():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_params(params)
    # matrices became {"q8","s8"} payloads; 1-D vectors stayed float
    assert set(qp["embed"]["table"]) == {"q8", "s8"}
    assert qp["embed"]["table"]["q8"].dtype == jnp.int8
    assert qp["final_norm"]["scale"].dtype == params["final_norm"][
        "scale"].dtype
    dq = dequantize_params(qp)
    rel = float(jnp.linalg.norm(dq["embed"]["table"]
                                - params["embed"]["table"])
                / jnp.linalg.norm(params["embed"]["table"]))
    assert rel < 0.01


def test_weight_quant_engine_runs_and_agrees():
    """int8 weights (dequant-on-apply) serve through the paged engine;
    greedy first tokens agree with the float engine on most prompts
    (same teacher-forced gate as the KV path, composed with int8 KV)."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    a = _first_tokens(model, params, n=24, seed=1)
    b = _first_tokens(model, params, n=24, seed=1, weight_quant="int8",
                      kv_quant="int8")
    match = sum(a[r] == b[r] for r in a) / len(a)
    assert match >= 0.8, f"weight+kv quant match {match:.3f}"


def test_weight_quant_flag_validation():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="weight_quant"):
        ServeEngine(model, params, paged=False, weight_quant="int8")
    with pytest.raises(ValueError, match="unknown weight_quant"):
        ServeEngine(model, params, weight_quant="int4")
    with pytest.raises(ValueError, match="self-K drafter"):
        ServeEngine(model, params, weight_quant="int8", spec_decode="self-2")
    # ngram drafting is weight-free and composes
    eng = ServeEngine(model, params, weight_quant="int8", spec_decode="ngram")
    assert eng.stats["weight_quant"] == "int8"
    eng.close()
