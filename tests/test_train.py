"""Training-stack tests: trainer, checkpointing, fault tolerance, optimizer,
compression, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.data import SyntheticTask, make_data_iter
from repro.models.api import build_model
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, int8_compress, int8_decompress,
                         lr_schedule)
from repro.train import (Trainer, TrainerConfig, latest_checkpoint,
                         restore_checkpoint, save_checkpoint)
from repro.train.checkpoint import checkpoint_steps
from repro.train.fault import NanGuard, restore_latest_valid


@pytest.fixture(scope="module")
def small():
    cfg = smoke_config("qwen3-1.7b").replace(remat="none")
    model = build_model(cfg)
    task = SyntheticTask(cfg, batch=4, seq_len=32)
    return cfg, model, task


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_schedule(cfg, 0)) == 0.0
    assert abs(float(lr_schedule(cfg, 10)) - 1.0) < 1e-6
    assert float(lr_schedule(cfg, 5)) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr_schedule(cfg, 10_000)) == pytest.approx(0.1, abs=1e-6)


@given(st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_clip_by_global_norm(max_norm):
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((2, 2), -4.0)}
    clipped, norm = clip_by_global_norm(tree, max_norm)
    from repro.optim import global_norm
    new_norm = float(global_norm(clipped))
    assert new_norm <= max(max_norm * 1.001, float(norm))


def test_adamw_moves_towards_gradient():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=0, decay_steps=10,
                      weight_decay=0.0)
    params = {"w": jnp.ones((3,))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.ones((3,))}
    new_params, state, stats = adamw_update(params, grads, state, cfg)
    assert (np.asarray(new_params["w"]) < 1.0).all()
    assert state["step"] == 1 and np.isfinite(stats["grad_norm"])


def test_adamw_bf16_moments():
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((3,))}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# int8 compression with error feedback
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1), st.floats(1e-3, 1e3))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = int8_compress(g)
    back = int8_decompress(q, s)
    # quantization error bounded by half a step
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_small_gradients():
    """EF property: a gradient too small to quantize is not lost forever."""
    from repro.core.comm import SerialComm
    from repro.optim.compress import compressed_psum
    big = jnp.asarray([10.0] + [0.0] * 63)
    tiny = jnp.asarray([10.0] + [0.01] * 63)   # 0.01 < s/2 = 10/254
    err = jnp.zeros((64,))
    comm = SerialComm()
    total = jnp.zeros((64,))
    for _ in range(20):
        mean, err = compressed_psum(tiny, err, comm)
        total = total + mean
    # after 20 steps the small coordinate's mass must have come through
    assert float(total[1]) == pytest.approx(0.2, rel=0.25)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable(small):
    cfg, model, task = small
    a = task.batch_at(7)
    b = task.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = make_data_iter(task, start_step=7)
    c = next(it)
    np.testing.assert_array_equal(a["tokens"], c["tokens"])


def test_data_is_learnable_structure(small):
    cfg, model, task = small
    b = task.batch_at(0)
    toks = np.asarray(b["tokens"][0])
    nxt = np.asarray(b["labels"][0])
    agree = ((31 * toks + 7) % cfg.vocab == nxt).mean()
    assert agree > 0.7            # ~90% bigram rule


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tiny_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.asarray(5, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    state = _tiny_state()
    save_checkpoint(d, 10, state)
    got, step = restore_checkpoint(d, state)
    assert step == 10
    np.testing.assert_allclose(got["params"]["w"], state["params"]["w"])


def test_checkpoint_keep_prunes(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, _tiny_state(), keep=2)
    assert checkpoint_steps(d) == [4, 5]


def test_corrupted_checkpoint_falls_back(tmp_path):
    d = str(tmp_path)
    state = _tiny_state()
    save_checkpoint(d, 1, state)
    save_checkpoint(d, 2, state)
    # corrupt the newest
    import glob
    npy = glob.glob(os.path.join(d, "step_00000002", "*.npy"))[0]
    arr = np.load(npy)
    np.save(npy, arr + 999)
    with pytest.raises(ValueError):
        restore_checkpoint(d, state, step=2)
    got, step = restore_latest_valid(d, state)
    assert step == 1                               # fell back


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tiny_state())
    # simulate crash mid-save: directory without COMMIT
    os.makedirs(os.path.join(d, "step_00000009"))
    assert latest_checkpoint(d) == 1


# ---------------------------------------------------------------------------
# Trainer end-to-end (+ resume, NaN guard)
# ---------------------------------------------------------------------------

def test_trainer_loss_decreases_and_resumes(tmp_path, small):
    cfg, model, task = small
    d = str(tmp_path)
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=30)
    t1 = Trainer(model, opt, TrainerConfig(steps=20, ckpt_dir=d,
                                           ckpt_every=10, log_every=100),
                 make_data_iter(task), log=lambda *_: None)
    r1 = t1.fit()
    assert r1["history"][-1]["loss"] < r1["history"][0]["loss"]
    t2 = Trainer(model, opt, TrainerConfig(steps=30, ckpt_dir=d,
                                           ckpt_every=10, log_every=100),
                 make_data_iter(task, start_step=20), log=lambda *_: None)
    r2 = t2.fit()
    assert t2.start_step == 20
    assert r2["history"][0]["step"] == 21


def test_nan_guard_rolls_back(tmp_path, small):
    cfg, model, task = small
    d = str(tmp_path)
    state = _tiny_state()
    save_checkpoint(d, 3, state)
    guard = NanGuard(d)
    assert guard.check(jnp.asarray(1.0), state) is None
    rolled = guard.check(jnp.asarray(float("nan")), state)
    assert rolled is not None
    restored, step, skip = rolled
    assert step == 3 and skip == 1
    # persistent NaN -> raises after max_rollbacks
    with pytest.raises(FloatingPointError):
        for _ in range(5):
            guard.check(jnp.asarray(float("nan")), state)


def test_reshard_state_roundtrip():
    from repro.train.fault import reshard_state
    state = _tiny_state()
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state)
    out = reshard_state(state, shardings)
    np.testing.assert_allclose(out["params"]["w"], state["params"]["w"])


def test_microbatch_accumulation_matches_full_batch(small):
    """accum_steps=2 over a batch == accum_steps=1 (same effective grads)."""
    from repro.train import make_train_step
    cfg, model, task = small
    opt = AdamWConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)
    params = model.init(jax.random.PRNGKey(3))
    batch = task.batch_at(0)
    s1 = {"params": params, "opt": adamw_init(params, opt)}
    s2 = jax.tree_util.tree_map(lambda x: x, s1)
    step1 = make_train_step(model, opt, accum_steps=1, donate=False)
    step2 = make_train_step(model, opt, accum_steps=2, donate=False)
    o1, m1 = step1(s1, batch)
    o2, m2 = step2(s2, batch)
    # losses averaged identically; params close (grad mean over microbatches
    # differs from full-batch grad only by masked-token weighting)
    w1 = jax.tree_util.tree_leaves(o1["params"])[0]
    w2 = jax.tree_util.tree_leaves(o2["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=2e-3, atol=2e-4)
