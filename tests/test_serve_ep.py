"""Expert-parallel serving over a 2-D ("expert", "model") mesh: parity.

Engines that partition whole MoE experts over an ``ep``-sized "expert" axis
(all-to-all dispatch/combine, replicated routing) must emit greedy token
streams bit-identical to the single-device engine — at ep=2, ep=4 and the
composed tp=2 x ep=2 mesh, with the prefix cache, forced preemption, int8 KV
quantization, speculative decode and load-aware expert re-placement in the
loop.  Per-expert telemetry must be mesh-invariant (routing is replicated).

Subprocess SPMD via ``--xla_force_host_platform_device_count=8`` (the main
pytest process must keep 1 device), like :mod:`tests.test_distributed`.
"""
from tests.test_distributed import run_spmd

_STREAMS = """
    from repro.configs import smoke_config
    from repro.models.api import build_model
    from repro.serve import ServeEngine

    def ep_mesh(ep, tp=1):
        return jax.make_mesh((ep, tp), ("expert", "model"))

    def streams(model, params, mesh, n_req=4, max_new=6, **kw):
        kw.setdefault("max_slots", 4); kw.setdefault("max_len", 96)
        eng = ServeEngine(model, params, mesh=mesh, paged=True, **kw)
        prompts = ([5, 17, 33, 2, 9], [7] * 9, [1, 2, 3] * 4,
                   [100, 200, 300, 4, 5, 6, 7])[:n_req]
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        done = eng.run_until_drained()
        eng.close()
        assert all(r.error is None for r in done)
        return {r.rid: r.output for r in done}, eng

    MOE = smoke_config("qwen3-moe-235b-a22b").replace(remat="none")
"""


def test_ep_paged_parity_and_telemetry():
    """ep=2, ep=4 and tp=2 x ep=2 MoE engines match the single-device
    engine token-for-token, and the per-expert telemetry (routed / dropped
    / per-expert counts) is identical at every mesh — routing is replicated
    so the measurements are global facts, not per-rank samples."""
    run_spmd(_STREAMS + """
    model = build_model(MOE)
    params = model.init(jax.random.PRNGKey(0))
    want, ref = streams(model, params, None, page_size=16, prefill_chunk=32)
    assert ref.stats["moe_tokens_routed"] > 0
    for ep, tp in ((2, 1), (4, 1), (2, 2)):
        got, eng = streams(model, params, ep_mesh(ep, tp), page_size=16,
                           prefill_chunk=32)
        assert (eng.ep, eng.tp) == (ep, tp)
        assert got == want, (ep, tp)
        for k in ("moe_tokens_routed", "moe_dropped_tokens", "expert_tokens"):
            assert eng.stats[k] == ref.stats[k], (ep, tp, k)

    # legacy 1-D ("model",) mesh is untouched by the expert axis
    got, eng = streams(model, params, jax.make_mesh((2,), ("model",)),
                       page_size=16, prefill_chunk=32)
    assert (eng.ep, eng.tp) == (1, 2) and got == want

    # dense families refuse an expert axis up front, with the fix named
    dense = build_model(smoke_config("qwen2-7b").replace(remat="none"))
    dp = dense.init(jax.random.PRNGKey(0))
    try:
        ServeEngine(dense, dp, max_slots=2, max_len=32, paged=True,
                    mesh=ep_mesh(2))
        raise AssertionError("dense + ep=2 must refuse")
    except ValueError as e:
        assert "dense family" in str(e) and "--mesh tp=N" in str(e)
    # ...and the expert axis needs the paged MoE path
    try:
        ServeEngine(dense, dp, max_slots=2, max_len=32, paged=False,
                    mesh=ep_mesh(2))
        raise AssertionError("non-paged + ep=2 must refuse")
    except ValueError as e:
        assert "paged" in str(e)
    print("ep paged parity OK")
    """)


def test_ep_parity_prefix_cache_and_preemption():
    """Prefix sharing and the preemption/recompute policy are host-side;
    under an expert mesh the streams and host counters stay identical."""
    run_spmd(_STREAMS + """
    model = build_model(MOE)
    params = model.init(jax.random.PRNGKey(0))

    P = list(range(1, 25))
    waves = ([P], [P, P], [P[:20] + [77, 78]])

    def run(mesh, prefix_cache, num_pages=None, max_len=128, max_new=12,
            max_slots=2):
        eng = ServeEngine(model, params, max_slots=max_slots, max_len=max_len,
                          paged=True, page_size=16, prefill_chunk=16,
                          num_pages=num_pages, prefix_cache=prefix_cache,
                          mesh=mesh)
        for wave in waves:
            for p in wave:
                eng.submit(p, max_new_tokens=max_new)
            eng.run_until_drained()
        outs = {r.rid: r.output for r in eng.finished}
        assert all(r.error is None for r in eng.finished)
        eng.close()
        return outs, eng.stats

    want, _ = run(None, False)
    base, s1 = run(None, True)
    assert base == want and s1["prefix_hits"] >= 3
    got, s2 = run(ep_mesh(2), True)
    assert got == want
    for k in ("prefix_hits", "prefix_hit_tokens", "cow_copies", "evictions"):
        assert s2[k] == s1[k], k

    # pool at the single-request minimum forces preemption on the expert
    # mesh too; the recompute policy keeps streams identical
    waves = ([[5, 17, 33, 2, 9, 1, 2, 3], [100, 200, 300, 4, 5, 6, 7, 8]],)
    want, s_off = run(None, False, num_pages=4, max_len=64, max_new=30)
    assert s_off["preemptions"] >= 1
    got, s_ep = run(ep_mesh(2, 2), False, num_pages=4, max_len=64, max_new=30)
    assert got == want and s_ep["preemptions"] >= 1
    print("ep prefix + preemption parity OK")
    """)


def test_ep_parity_quant_and_spec_decode():
    """int8 KV pages and ngram speculative decode compose with the expert
    axis: quant-on ep=2 streams equal quant-on serial streams, spec-on ep=2
    equals the spec-OFF serial reference, and the draft counters are
    mesh-invariant."""
    run_spmd(_STREAMS + """
    model = build_model(MOE)
    params = model.init(jax.random.PRNGKey(0))

    want, _ = streams(model, params, None, page_size=8, prefill_chunk=16,
                      kv_quant="int8")
    got, eng = streams(model, params, ep_mesh(2), page_size=8,
                       prefill_chunk=16, kv_quant="int8")
    assert eng.stats["kv_quant"] == "int8" and got == want
    got, _ = streams(model, params, ep_mesh(2, 2), page_size=8,
                     prefill_chunk=16, kv_quant="int8")
    assert got == want, "kv quant ep x tp parity"

    plain, _ = streams(model, params, None, page_size=8, prefill_chunk=16,
                       max_new=10)
    spec1, e1 = streams(model, params, None, page_size=8, prefill_chunk=16,
                        max_new=10, spec_decode="ngram")
    assert spec1 == plain and e1.stats["draft_proposed"] > 0
    spec2, e2 = streams(model, params, ep_mesh(2), page_size=8,
                        prefill_chunk=16, max_new=10, spec_decode="ngram")
    assert spec2 == plain, "ep spec parity"
    for k in ("draft_proposed", "draft_accepted", "acceptance_rate"):
        assert e1.stats[k] == e2.stats[k], k
    print("ep quant + spec parity OK")
    """)


def test_ep_placement_rebalance_parity():
    """Load-aware re-placement on a live expert mesh: the weight
    permutation + dispatch-map swap between ticks leaves token streams
    bitwise unchanged at ep=2 and ep=4, and re-placement reduces (or at
    worst preserves) the measured rank imbalance."""
    run_spmd(_STREAMS + """
    model = build_model(MOE)
    params = model.init(jax.random.PRNGKey(0))
    want, ref = streams(model, params, None, page_size=16, prefill_chunk=32)
    for ep in (2, 4):
        got, eng = streams(model, params, ep_mesh(ep), page_size=16,
                           prefill_chunk=32, placement_interval=2)
        assert got == want, ep
        assert eng.stats["placement_updates"] >= 1
        assert eng.placement is not None
        assert eng.stats["expert_tokens"] == ref.stats["expert_tokens"]
        # the live plan is a full slot assignment (every physical slot holds
        # some expert's weights) and every non-evicted expert is reachable
        pe = eng.placement.phys_expert
        assert sorted(set(pe.tolist())) and (pe >= 0).all()
        assert eng.stats["expert_imbalance"] >= 1.0
    print("ep placement parity OK")
    """)
