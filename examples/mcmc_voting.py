"""Paper §4.1: ideal-point MCMC on synthetic roll-call data (task farm).

    PYTHONPATH=src python examples/mcmc_voting.py
"""
import jax
import numpy as np

from repro.apps import mcmc

print("generating synthetic legislature (80 members, 200 votes)...")
y, truth = mcmc.make_synthetic_votes(jax.random.PRNGKey(7), n_leg=80,
                                     n_votes=200)

problem = mcmc.IdealPointProblem(y, n_chains=4, n_iter=200, burn=100)
print("running 4 Gibbs chains through the task farm...")
res = mcmc.solve_vmap(problem)

corr = np.corrcoef(np.asarray(res["x_mean"]), np.asarray(truth["x"]))[0, 1]
rhat = np.asarray(res["rhat"])
print(f"|corr(estimated, true ideal points)| = {abs(corr):.3f}")
print(f"split-R-hat: median {np.median(rhat):.3f}, max {rhat.max():.3f}")

# the most extreme legislators, as a political scientist would read them
order = np.argsort(np.asarray(res["x_mean"]))
print("most left-leaning members:", order[:5])
print("most right-leaning members:", order[-5:])
