"""Paper §4.3: Boussinesq ocean waves via additive Schwarz.

The same Jacobi "legacy kernel" runs (a) on the global domain and (b) per
subdomain under the generic Schwarz layer; the solutions must agree.

    PYTHONPATH=src python examples/boussinesq_waves.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/boussinesq_waves.py
"""
import jax
import numpy as np

from repro.apps import boussinesq as bq

p = bq.BoussinesqParams(nx=64, ny=64, dt=0.02, eps=0.3, alpha=0.05)
steps = 60

print(f"== serial solve ({p.nx}x{p.ny}, {steps} steps) ==")
eta_s, phi_s, hist_s = bq.run_serial(p, steps=steps)
print(f"   mass drift: {abs(float(hist_s['mass'][-1] - hist_s['mass'][0])):.2e}")

n_dev = jax.device_count()
print(f"== additive Schwarz over {n_dev} subdomain(s) ==")
mesh = jax.make_mesh((n_dev,), ("data",))
eta_p, phi_p, hist_p = bq.run_parallel(mesh, p, steps=steps)
err = np.abs(np.asarray(eta_s) - np.asarray(eta_p)).max()
print(f"   max |eta_serial - eta_schwarz| = {err:.2e}")
print(f"   mean Schwarz iterations/step: "
      f"{float(np.asarray(hist_p['iters']).mean()):.1f}")
assert err < 1e-4
print("serial and Schwarz-parallel solutions agree")
