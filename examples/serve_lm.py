"""Continuous-batching serving demo: requests of different lengths stream
through fixed decode slots (the paper's dynamic-population pattern).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --spec-decode ngram

``--spec-decode ngram|self-K`` turns on speculative multi-token decode: a
drafter *function* proposes continuation tokens and one batched verify
forward accepts the prefix the target model agrees with — greedy streams
stay bit-identical, ticks go down.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--spec-decode", default="off", metavar="ngram|self-K|off",
                help="speculative decode drafter (default off)")
ap.add_argument("--spec-k", type=int, default=4,
                help="max draft tokens per verify window")
ap.add_argument("--kv-quant", choices=("int8", "off"), default="off",
                help="int8 KV pages with fused in-attention dequant "
                "(~2-4x concurrent slots at equal HBM)")
args = ap.parse_args()

cfg = smoke_config("qwen2-7b").replace(remat="none")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = ServeEngine(model, params, max_slots=4, max_len=128,
                  spec_decode=None if args.spec_decode == "off"
                  else args.spec_decode,
                  spec_k=args.spec_k,
                  kv_quant=None if args.kv_quant == "off"
                  else args.kv_quant)
rng = np.random.default_rng(0)

print("submitting 12 requests with prompt lengths 4..40...")
for i in range(12):
    plen = int(rng.integers(4, 40))
    eng.submit(rng.integers(0, cfg.vocab, plen),
               max_new_tokens=int(rng.integers(8, 24)))

t0 = time.perf_counter()
done = eng.run_until_drained()
dt = time.perf_counter() - t0

toks = sum(len(r.output) for r in done)
ttft = [r.first_token_at - r.submitted_at for r in done]
print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
      f"({toks/dt:.1f} tok/s on CPU)")
print(f"decode ticks: {eng.stats['ticks']} "
      f"(vs {toks} for one-at-a-time decoding)")
print(f"slots reused across {eng.stats['prefills']} prefills; "
      f"mean TTFT {1e3*np.mean(ttft):.0f}ms")
if eng.kv_quant is not None:
    print(f"kv quant [{eng.stats['kv_quant']}]: "
          f"{eng.stats['kv_bytes_per_token']} KV bytes/token")
if eng.drafter is not None:
    s = eng.stats
    print(f"spec decode [{args.spec_decode}]: proposed={s['draft_proposed']} "
          f"accepted={s['draft_accepted']} "
          f"acceptance_rate={s['acceptance_rate']:.2f}")
print("sample output:", done[0].output)
