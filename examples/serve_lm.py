"""Continuous-batching serving demo: requests of different lengths stream
through fixed decode slots (the paper's dynamic-population pattern).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine

cfg = smoke_config("qwen2-7b").replace(remat="none")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = ServeEngine(model, params, max_slots=4, max_len=128)
rng = np.random.default_rng(0)

print("submitting 12 requests with prompt lengths 4..40...")
for i in range(12):
    plen = int(rng.integers(4, 40))
    eng.submit(rng.integers(0, cfg.vocab, plen),
               max_new_tokens=int(rng.integers(8, 24)))

t0 = time.perf_counter()
done = eng.run_until_drained()
dt = time.perf_counter() - t0

toks = sum(len(r.output) for r in done)
ttft = [r.first_token_at - r.submitted_at for r in done]
print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
      f"({toks/dt:.1f} tok/s on CPU)")
print(f"decode ticks: {eng.stats['ticks']} "
      f"(vs {toks} for one-at-a-time decoding)")
print(f"slots reused across {eng.stats['prefills']} prefills; "
      f"mean TTFT {1e3*np.mean(ttft):.0f}ms")
print("sample output:", done[0].output)
