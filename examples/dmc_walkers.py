"""Paper §4.2: diffusion Monte Carlo for a 3D harmonic trap, serial AND
SPMD-parallel with dynamic load balancing (run with more fake devices to see
the rebalancer work):

    PYTHONPATH=src python examples/dmc_walkers.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/dmc_walkers.py
"""
import jax
import numpy as np

from repro.apps import dmc

print("== serial DMC (paper's time_integration + Walkers class) ==")
out = dmc.run_serial(n_walkers=400, timesteps=500, tau=0.02)
print(f"   E0 estimate: {float(out['e0_estimate']):.4f}  (exact: 1.5)")
print(f"   final population: {int(out['counts'][-1])}")

n_dev = jax.device_count()
print(f"== SPMD DMC over {n_dev} device(s), load-balanced every step ==")
mesh = jax.make_mesh((n_dev,), ("data",))
out = dmc.run_parallel(mesh, n_walkers=128 * n_dev, timesteps=400, tau=0.02)
lc = np.asarray(out["local_counts"])[-1]
print(f"   E0 estimate: {float(out['e0_estimate']):.4f}")
print(f"   load-balancer fired {int(out['rebalances'])} times")
print(f"   final per-shard walker counts: {lc} (skew "
      f"{lc.max() / max(lc.min(), 1):.2f})")
