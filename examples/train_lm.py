"""End-to-end LM training driver: a ~100M-param qwen3-style model trained on
the synthetic bigram language for a few hundred steps, with checkpointing,
NaN guard, and resume — the full production path on whatever devices exist.

Default is a ~10M model / 200 steps so the demo finishes in minutes on CPU;
pass ``--params-100m`` for the full-size run (same code, bigger config):

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --params-100m --steps 300
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import SyntheticTask, make_data_iter
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--params-100m", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

base = get_config("qwen3-1.7b")
if args.params_100m:
    cfg = base.replace(n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
                       head_dim=64, d_ff=2048, vocab=32000, tp=1,
                       dtype="float32", remat="none")
else:
    cfg = base.replace(n_layers=6, d_model=256, n_heads=4, n_kv_heads=2,
                       head_dim=64, d_ff=1024, vocab=8192, tp=1,
                       dtype="float32", remat="none")

model = build_model(cfg)
print(f"model: {model.n_params()/1e6:.1f}M params "
      f"({cfg.n_layers}L d{cfg.d_model} vocab {cfg.vocab})")

task = SyntheticTask(cfg, batch=args.batch, seq_len=args.seq)
trainer = Trainer(
    model,
    AdamWConfig(peak_lr=1e-3, warmup_steps=args.steps // 10,
                decay_steps=args.steps),
    TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                  log_every=20),
    make_data_iter(task))
result = trainer.fit()
h = result["history"]
print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over {len(h)} steps")
print(f"checkpoints in {args.ckpt_dir} (re-run to resume)")
