"""Quickstart: the paper's §2 parabola example through all four executors of
the function-centric runtime (every tier drives the SAME three functions).

    PYTHONPATH=src python examples/quickstart.py

1. ``solve_problem`` / ``SerialExecutor``     — the paper's serial loop.
2. ``vmap_solve_problem`` / ``VmapExecutor``  — vectorized on one device.
3. ``parallel_solve_problem`` / ``MeshExecutor`` — SPMD over a device mesh
   (here 1 CPU device; on a pod, the production mesh — code unchanged).
4. ``ThreadFarmExecutor``                     — concurrent host-level farm
   (work stealing + straggler re-dispatch) for separately-jitted programs.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_problem, vmap_solve_problem, parallel_solve_problem
from repro.core.runtime import ThreadFarmExecutor

M, N, L = 32, 50, 10.0


# --- the user's three functions (the paper's Parabola class) ----------------

class Parabola:
    def initialize(self):
        x = np.linspace(0, L, N)
        vals = np.linspace(-1, 1, M)
        self.input_args = [((x,), {"a": a, "b": b, "c": 5.0})
                           for a in vals for b in vals]
        return self.input_args

    def func(self, x, a=0.0, b=0.0, c=1.0):
        return a * x ** 2 + b * x + c

    def finalize(self, output):
        return [(args[1]["a"], args[1]["b"])
                for args, out in zip(self.input_args, output)
                if np.min(out) < 0]


print("== tier 1: paper-faithful serial solve_problem ==")
p = Parabola()
ab = solve_problem(p.initialize, p.func, p.finalize)
print(f"   {len(ab)} of {M*M} (a,b) combinations give f < 0 somewhere")


# --- tier 2/3: the same problem as stacked-array tasks ----------------------

x = jnp.linspace(0, L, N)
vals = jnp.linspace(-1, 1, M)
aa, bb = jnp.meshgrid(vals, vals, indexing="ij")


def initialize():
    return {"a": aa.ravel(), "b": bb.ravel()}


def func(task):
    return task["a"] * x ** 2 + task["b"] * x + 5.0


def finalize(out):
    neg = (out.min(axis=-1) < 0)
    return int(neg.sum())


print("== tier 2: vmapped on one device ==")
n_neg = vmap_solve_problem(initialize, func, finalize)
print(f"   {n_neg} negative combinations (matches: {n_neg == len(ab)})")

print("== tier 3: SPMD task farm over the available mesh ==")
mesh = jax.make_mesh((jax.device_count(),), ("data",))
n_neg = parallel_solve_problem(initialize, func, finalize, mesh)
print(f"   {n_neg} negative combinations on a {jax.device_count()}-device mesh")
assert n_neg == len(ab)

print("== tier 4: concurrent host-level thread farm ==")
farm = ThreadFarmExecutor(num_workers=8, deadline_factor=3.0)
n_neg = farm.run(initialize, func, finalize)
print(f"   {n_neg} negative combinations on the thread farm")
assert n_neg == len(ab)
print("quickstart OK")
